"""Fleet-scale rounds: 10³ → 10⁶ simulated devices per round.

Sweeps the fleet size of a two-tier stacked topology under the cohort
scheduler (one vectorized batch dispatch per round, v2 counter-based RNG
stream) and a :class:`repro.data.VirtualFleetDataset` whose shards are
generated inside the jit boundary — no per-device Python objects, no
(N, m, dim) host array — and reports per size: devices per round, final
training loss and cloud-uplink bytes (deterministic accounting — gated),
plus warm round wall-clock, devices/second throughput and peak host RSS
(machine-dependent — gate-ignored).  A 64-device record cross-checks the
fleet path against the per-device event scheduler on a shared scenario:
identical virtual times and byte accounting, losses equal to float
tolerance (the equivalence the fleet tests assert).

Quick mode (CI + the committed ``BENCH_fleet.json``) sweeps 10³→10⁵; full
mode adds the 10⁶ record with every metric suffixed ``_ungated`` so a
full-mode refresh never perturbs the quick-mode baseline the gate diffs.

Emits ``name,us_per_call,derived`` rows like every other benchmark module;
``collect()`` returns a JSON-ready dict for ``run.py --json``
(→ ``BENCH_fleet.json``).
"""
from __future__ import annotations

import resource
from typing import Dict, List

import jax

from repro.data import VirtualFleetDataset
from repro.edge import array_bimodal_fleet, bimodal_fleet
from repro.fl import run_hier_simulation
from repro.hier import (HierConfig, stacked_two_tier, two_tier_topology)
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

from .common import emit

SEED = 42
QUICK_SIZES = (1_000, 10_000, 100_000)
FULL_SIZES = QUICK_SIZES + (1_000_000,)
DIM, CLASSES, SAMPLES = 16, 4, 16
# in-jit shard buffer cap: above this cohort size the virtual batch update
# runs in chunks (at most two compiled shapes)
COHORT_CHUNK = 131_072


def _params():
    return get_model(ArchConfig(name="lr", family="logreg", input_dim=DIM,
                                num_classes=CLASSES)
                     ).init(jax.random.PRNGKey(0))


def _cfg() -> HierConfig:
    return HierConfig(aggregator="hier_contextual", lr=0.1, mu=0.0,
                      batch_size=8, min_epochs=1, max_epochs=1)


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _fleet_record(n_dev: int, rounds: int, params) -> dict:
    gws = max(4, n_dev // 500)
    ds = VirtualFleetDataset(num_devices=n_dev, samples_per_device=SAMPLES,
                             dim=DIM, num_classes=CLASSES, seed=3)
    topo = stacked_two_tier(array_bimodal_fleet(n_dev), gws)
    r = run_hier_simulation(
        f"fleet_{n_dev}", logistic_loss, logistic_apply, params, ds, _cfg(),
        topo, num_rounds=rounds, selection_seed=SEED, eval_every=rounds,
        scheduler_mode="cohort", rng_stream="v2",
        cohort_chunk=COHORT_CHUNK if n_dev > COHORT_CHUNK else None)
    steady = r.engine.get("steady_wall_time_per_round_s") or 0.0
    return {
        "scenario": "fleet", "fleet_size": n_dev, "num_gateways": gws,
        "devices_per_round": r.dispatched // rounds,
        "final_train_loss": r.train_loss[-1],
        "cloud_uplink_bytes": r.cloud_uplink_bytes,
        "total_bytes": r.total_bytes,
        "t_virtual_end": r.times[-1],
        # machine-dependent throughput columns (gate-ignored)
        "warm_round_wall_time_ms": steady * 1e3,
        "devices_per_s": (r.dispatched / rounds) / steady if steady else 0.0,
        "peak_rss_mb": _peak_rss_mb(),
        **r.engine,
    }


def _equivalence_record(rounds: int, params) -> dict:
    """Same 64-device/4-gateway scenario down both paths: per-device event
    scheduler over materialized shards vs cohort scheduler over the virtual
    fleet.  Virtual clocks and byte ledgers must agree exactly; losses to
    float tolerance."""
    n_dev, gws = 64, 4
    ds = VirtualFleetDataset(num_devices=n_dev, samples_per_device=SAMPLES,
                             dim=DIM, num_classes=CLASSES, seed=3)
    kw = dict(num_rounds=rounds, selection_seed=SEED, eval_every=rounds,
              rng_stream="v2")
    ev = run_hier_simulation(
        "fleet_eq_event", logistic_loss, logistic_apply, params,
        ds.materialize(), _cfg(), two_tier_topology(bimodal_fleet(n_dev), gws),
        scheduler_mode="event", **kw)
    co = run_hier_simulation(
        "fleet_eq_cohort", logistic_loss, logistic_apply, params, ds, _cfg(),
        stacked_two_tier(array_bimodal_fleet(n_dev), gws),
        scheduler_mode="cohort", **kw)
    gap = max(abs(a - b) for a, b in zip(ev.train_loss, co.train_loss))
    return {
        "scenario": "equivalence_64", "fleet_size": n_dev,
        "num_gateways": gws, "final_train_loss": co.train_loss[-1],
        "loss_gap_vs_event": gap,
        "cloud_uplink_bytes": co.cloud_uplink_bytes,
        "bytes_equal_event_path": co.cloud_uplink_bytes
        == ev.cloud_uplink_bytes and co.total_bytes == ev.total_bytes,
        "times_equal_event_path": co.times == ev.times,
    }


def collect(rounds: int = 3, quick: bool = True) -> Dict[str, List[dict]]:
    """Run the sweep and return JSON-ready records (also used by --json)."""
    params = _params()
    records = [_equivalence_record(rounds, params)]
    for n_dev in QUICK_SIZES:
        records.append(_fleet_record(n_dev, rounds, params))
    if not quick:
        # the 10⁶ demonstration rides gate-ignored metric names so a
        # full-mode refresh never perturbs the quick-mode baseline
        rec = _fleet_record(FULL_SIZES[-1], rounds, params)
        records.append({
            "scenario": "fleet_1m_ungated",
            **{f"{k}_ungated": v for k, v in rec.items()
               if k != "scenario"},
        })
    return {"benchmark": "fleet_scale", "rounds": rounds,
            "records": records}


def run(rounds: int = 3, quick: bool = True) -> Dict[str, List[dict]]:
    results = collect(rounds, quick)
    for rec in results["records"]:
        size = rec.get("fleet_size", rec.get("fleet_size_ungated", 0))
        loss = rec.get("final_train_loss",
                       rec.get("final_train_loss_ungated", float("nan")))
        dps = rec.get("devices_per_s", rec.get("devices_per_s_ungated", 0.0))
        wall = rec.get("warm_round_wall_time_ms",
                       rec.get("warm_round_wall_time_ms_ungated", 0.0))
        derived = f"size={size};loss={loss:.4f}"
        if "loss_gap_vs_event" in rec:
            derived += (f";gap_vs_event={rec['loss_gap_vs_event']:.2e};"
                        f"bytes_equal={rec['bytes_equal_event_path']}")
        else:
            derived += f";devices_per_s={dps:.0f};warm_round={wall:.1f}ms"
        emit(f"fleet_scale/{rec['scenario']}/n{size}", wall * 1e3, derived)
    return results
