"""Paper Figures 4 & 5: FedAvg / FedProx / FOLB vs the contextual versions —
training loss and test accuracy on the heterogeneous datasets."""
from __future__ import annotations

from .common import dataset, emit, run_fl

ALGOS = [
    ("FedAvg", "fedavg", dict()),
    ("FedProx(mu=0.1)", "fedavg", dict(mu=0.1)),
    ("FOLB", "folb", dict(mu=0.1)),
    ("FedAvg(Contextual)", "contextual", dict()),
    ("FedProx(Contextual,mu=0.1)", "contextual", dict(mu=0.1)),
]

SCAFFOLD = [("SCAFFOLD", "fedavg"), ("SCAFFOLD(Contextual)", "contextual")]


def run(rounds: int = 40) -> None:
    import jax

    from repro.fl import ServerConfig, run_scaffold
    from repro.models import get_model
    from repro.models.config import ArchConfig
    from repro.models.logistic import logistic_apply, logistic_loss

    for ds_name in ("mnist", "synthetic_1_1"):
        ds = dataset(ds_name)
        for label, agg, kw in ALGOS:
            r = run_fl(f"{ds_name}/{label}", agg, ds, rounds, **kw)
            emit(f"fig4_5/{ds_name}/{label}",
                 r.wall_time / max(rounds, 1) * 1e6,
                 f"final_loss={r.train_loss[-1]:.4f};"
                 f"final_acc={r.test_acc[-1]:.4f};"
                 f"volatility={r.loss_volatility():.5f}")
        # SCAFFOLD (paper ref [10]) + the beyond-paper contextual hybrid
        mcfg = ArchConfig(name="lr", family="logreg",
                          input_dim=ds.x.shape[-1],
                          num_classes=ds.num_classes)
        params = get_model(mcfg).init(jax.random.PRNGKey(0))
        for label, agg in SCAFFOLD:
            cfg = ServerConfig(aggregator=agg, num_devices=ds.num_devices,
                               clients_per_round=10, lr=0.2, batch_size=10,
                               min_epochs=1, max_epochs=20)
            r = run_scaffold(label, logistic_loss, logistic_apply, params,
                             ds, cfg, num_rounds=rounds, selection_seed=42)
            emit(f"fig4_5/{ds_name}/{label}",
                 r.wall_time / max(rounds, 1) * 1e6,
                 f"final_loss={r.train_loss[-1]:.4f};"
                 f"final_acc={r.test_acc[-1]:.4f};"
                 f"volatility={r.loss_volatility():.5f}")
