"""Roofline table (deliverable g): analytic terms (calibrated — see
tests/test_roofline_calibration.py) + compiled-artifact cross-checks from
experiments/dryrun/*.json.

Emits one row per (arch × shape × mesh) with the three terms, the dominant
bottleneck, MODEL_FLOPS/analytic ratio, and the artifact's collective
schedule summary."""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ASSIGNED, get_config
from repro.launch.analytic import analytic_roofline
from repro.launch.shapes import INPUT_SHAPES, arch_for_shape

from .common import emit


def run(dryrun_dir: str = "experiments/dryrun") -> None:
    art = {}
    for fn in glob.glob(os.path.join(dryrun_dir, "*.json")):
        with open(fn) as f:
            rec = json.load(f)
        art[(rec["arch"], rec["shape"], rec["mesh"])] = rec

    for arch in ASSIGNED:
        for shape_name in INPUT_SHAPES:
            shape = INPUT_SHAPES[shape_name]
            cfg = arch_for_shape(get_config(arch), shape)
            tag = f"roofline/{arch}/{shape_name}"
            if cfg is None:
                emit(tag, 0.0, "status=skip;reason=see DESIGN.md §5")
                continue
            r = analytic_roofline(cfg, shape)
            rec = art.get((arch, shape_name, "single"))
            extra = ""
            if rec and rec.get("status") == "ok":
                extra = (f";compiled=ok;coll_ops={rec['collectives']['count']};"
                         f"artifact_mem_s={rec['roofline']['memory_s']:.2e}")
            elif rec:
                extra = f";compiled={rec.get('status')}"
            emit(tag, r.compute_s * 1e6,
                 f"compute_s={r.compute_s:.3e};memory_s={r.memory_s:.3e};"
                 f"collective_s={r.collective_s:.3e};"
                 f"bottleneck={r.bottleneck};useful={r.useful_ratio:.2f}"
                 + extra)
