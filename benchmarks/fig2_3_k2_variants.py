"""Paper Figures 2 & 3: contextual variants with K₂ ∈ {N, 20, 10, 0} and
different proximal μ — training loss and test accuracy trajectories."""
from __future__ import annotations

from .common import dataset, emit, run_fl


def run(rounds: int = 25) -> None:
    ds = dataset("mnist")
    for mu in (0.0, 0.1):
        for k2 in (30, 20, 10, 0):
            r = run_fl(f"mu={mu}/k2={k2}", "contextual", ds, rounds, mu=mu,
                       grad_sample=k2)
            emit(f"fig2_3/mu={mu}/K2={k2}",
                 r.wall_time / max(rounds, 1) * 1e6,
                 f"final_loss={r.train_loss[-1]:.4f};"
                 f"final_acc={r.test_acc[-1]:.4f};"
                 f"volatility={r.loss_volatility():.5f}")
