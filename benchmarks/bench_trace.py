"""Derive committed bench JSON from a streamed ``.jsonl`` trace.

Since the telemetry PR, every bench run streams an append-only event trace
(``BENCH_<name>.jsonl``, written by ``repro.obs.JsonlTracker``) and the
committed ``BENCH_<name>.json`` snapshot is *derived* from that trace — the
trace is the single source of truth.  The bench's JSON-ready results enter
the stream as summary events carrying one of four marker keys
(``benchmarks.common.publish_bench`` writes them):

  * ``_bench_meta``   — dict of top-level scalar fields (benchmark, rounds…)
  * ``_bench_record`` — one entry of the ``records`` list, in order
  * ``_bench_block``  — ``{"key", "value"}``: a named dict block (e.g. the
    compress bench's ``acceptance``)
  * ``_bench_list``   — ``{"key", "value"}``: one entry of a named list
    (e.g. the kernel bench's ``autotune`` dump)

Everything else in the trace (per-round sim metrics, ledger transfers,
autotune decisions) is live telemetry and does not shape the JSON.

Stdlib-only on purpose: ``check_regression.py`` and ``summarize_trace.py``
run in CI before/without jax, and import this next to themselves.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterator, List


def iter_events(path: str) -> Iterator[Dict[str, Any]]:
    """Yield the parsed events of one jsonl trace, in stream order."""
    with open(path) as f:
        for line in f:
            if line.strip():
                yield json.loads(line)


def derive_bench_json(path: str) -> Dict[str, Any]:
    """Rebuild the ``BENCH_<name>.json`` payload from its trace."""
    out: Dict[str, Any] = {}
    records: List[dict] = []
    for event in iter_events(path):
        m = event["metrics"]
        if "_bench_meta" in m:
            out.update(m["_bench_meta"])
        elif "_bench_record" in m:
            records.append(m["_bench_record"])
        elif "_bench_block" in m:
            out[m["_bench_block"]["key"]] = m["_bench_block"]["value"]
        elif "_bench_list" in m:
            out.setdefault(m["_bench_list"]["key"], []).append(
                m["_bench_list"]["value"])
    if records:
        out["records"] = records
    return out
