"""Derive committed bench JSON from a streamed ``.jsonl`` trace.

Since the telemetry PR, every bench run streams an append-only event trace
(``BENCH_<name>.jsonl``, written by ``repro.obs.JsonlTracker``) and the
committed ``BENCH_<name>.json`` snapshot is *derived* from that trace — the
trace is the single source of truth.  The bench's JSON-ready results enter
the stream as summary events carrying one of four marker keys
(``benchmarks.common.publish_bench`` writes them):

  * ``_bench_meta``   — dict of top-level scalar fields (benchmark, rounds…)
  * ``_bench_record`` — one entry of the ``records`` list, in order
  * ``_bench_block``  — ``{"key", "value"}``: a named dict block (e.g. the
    compress bench's ``acceptance``)
  * ``_bench_list``   — ``{"key", "value"}``: one entry of a named list
    (e.g. the kernel bench's ``autotune`` dump)

Everything else in the trace (per-round sim metrics, ledger transfers,
autotune decisions) is live telemetry and does not shape the JSON.

Stdlib-only on purpose: ``check_regression.py`` and ``summarize_trace.py``
run in CI before/without jax, and import this next to themselves.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterator, List


def iter_events(path: str) -> Iterator[Dict[str, Any]]:
    """Yield the parsed events of one jsonl trace, in stream order."""
    with open(path) as f:
        for line in f:
            if line.strip():
                yield json.loads(line)


class BenchFold:
    """Incremental ``BENCH_<name>.json`` derivation: feed events one at a
    time (so a summarizer can fold the payload inside its own single pass
    over the trace) and read ``payload()`` at the end."""

    def __init__(self) -> None:
        self._out: Dict[str, Any] = {}
        self._records: List[dict] = []

    def add(self, event: Dict[str, Any]) -> None:
        m = event["metrics"]
        if "_bench_meta" in m:
            self._out.update(m["_bench_meta"])
        elif "_bench_record" in m:
            self._records.append(m["_bench_record"])
        elif "_bench_block" in m:
            self._out[m["_bench_block"]["key"]] = m["_bench_block"]["value"]
        elif "_bench_list" in m:
            self._out.setdefault(m["_bench_list"]["key"], []).append(
                m["_bench_list"]["value"])

    def payload(self) -> Dict[str, Any]:
        out = dict(self._out)
        if self._records:
            out["records"] = list(self._records)
        return out


def derive_bench_json(path: str) -> Dict[str, Any]:
    """Rebuild the ``BENCH_<name>.json`` payload from its trace."""
    fold = BenchFold()
    for event in iter_events(path):
        fold.add(event)
    return fold.payload()


# mirrors repro.obs.spans.RESERVED_KEYS (kept in sync by tests/test_obs.py)
SPAN_RESERVED = ("name", "path", "depth", "flat", "t0_wall", "dur_wall_s",
                 "t0_virtual", "dur_virtual_s")


def span_fields(event: Dict[str, Any]) -> Dict[str, Any]:
    """Normalized field dict of one ``kind == "span"`` raw trace event:
    spans are emitted through the root tracker so keys normally arrive
    bare, but a span logged under a scoped view carries the scope prefix —
    strip it so every trace tool sees one layout."""
    m = event["metrics"]
    scope = event.get("scope", "")
    if scope:
        prefix = scope + "/"
        m = {(k[len(prefix):] if k.startswith(prefix) else k): v
             for k, v in m.items()}
    return m


def iter_spans(path: str) -> Iterator[Dict[str, Any]]:
    """Yield the normalized span events of one trace, in stream order."""
    for event in iter_events(path):
        if event.get("kind") == "span":
            yield span_fields(event)
