"""Paper Figure 6: number of rounds to reach accuracy levels per dataset —
the paper's headline 'factor of three or more' convergence-speed metric."""
from __future__ import annotations

from .common import dataset, emit, run_fl

LEVELS = {"mnist": (0.5, 0.6, 0.7), "femnist": (0.3, 0.4, 0.5),
          "synthetic_iid": (0.5, 0.6, 0.7), "synthetic_1_1": (0.5, 0.6, 0.7)}


def run(rounds: int = 50) -> None:
    for ds_name, levels in LEVELS.items():
        ds = dataset(ds_name)
        for label, agg, kw in (("FedAvg", "fedavg", {}),
                               ("FOLB", "folb", dict(mu=0.1)),
                               ("Contextual", "contextual", {})):
            r = run_fl(f"{ds_name}/{label}", agg, ds, rounds, **kw)
            marks = ";".join(
                f"acc{int(l*100)}={r.rounds_to_accuracy(l) or '>' + str(rounds)}"
                for l in levels)
            emit(f"fig6/{ds_name}/{label}",
                 r.wall_time / max(rounds, 1) * 1e6, marks)
