"""Paper Figure 7: the computed aggregation variables α_k at early /
near-converged / converged stages — variance and range per stage."""
from __future__ import annotations

import numpy as np

from .common import dataset, emit, run_fl


def run(rounds: int = 30) -> None:
    ds = dataset("mnist")
    r = run_fl("ctx", "contextual", ds, rounds)
    stages = {"early": 0, "near_converged": rounds // 2,
              "converged": rounds - 1}
    for stage, idx in stages.items():
        a = np.asarray(r.alpha_history[idx])
        emit(f"fig7/alpha/{stage}", 0.0,
             f"mean={a.mean():.4f};std={a.std():.4f};"
             f"min={a.min():.4f};max={a.max():.4f}")
