"""Serving bench: continuous-batching engine vs the per-token jit loop.

Three scenarios close the train-to-serve loop end to end:

  * ``decode_throughput`` — tokens/s serving a heavy-tailed request
    workload (1-in-8 long generations, the canonical continuous-batching
    motivation) with the engine's jitted multi-step scan (donated cache,
    slots recycled the chunk a request retires) against the seed serving
    path (``launch/serve.py`` pre-engine): fixed lockstep batches, one
    jitted batch prefill plus one host-dispatched jit per token, each
    batch held until its LONGEST request finishes.  Same reduced arch,
    same batch width, same requests in the same order.  The acceptance
    claim: engine ≥ 5× the lockstep loop.  Both sides are warmed and
    best-of-``reps`` timed on the same jitted callables (a fresh
    ``DecodeEngine`` would recompile).  Raw tokens/s are
    machine-dependent (gate-ignored); the ``meets_speedup_5x`` boolean is
    the gated fact, and it holds with margin because the step-count gap
    is structural: the lockstep path spends ``batches × longest`` decode
    dispatches while the engine retires shorts at chunk boundaries and
    keeps every slot on long work (``engine_decode_steps`` ≈ the long
    request length; ``seed_decode_calls`` ≈ 8× that).
  * ``publish_fidelity`` — a tiny logreg hierarchical sim publishes every
    round's aggregated params through ``publish_fn``; re-evaluating each
    published tree with ``global_train_loss`` must match the simulation's
    own per-round ``train_loss`` to float precision (the bus carries the
    exact trees the trainer evaluated, not stale or torn copies).
  * ``hot_swap`` — the offline harness replays a synthetic trace while the
    sim's round schedule publishes perturbed LM versions mid-flight:
    swap counts, slot occupancy, and the staleness-vs-loss record are
    deterministic under the virtual clock; swap stall (publish→adopt wall
    latency) is measured but gate-ignored.

Emits ``name,us_per_call,derived`` rows; ``run.py --json`` derives
``BENCH_serve.json`` from the streamed trace.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.edge import bimodal_fleet
from repro.fl import run_hier_simulation
from repro.fl.metrics import global_train_loss
from repro.hier import HierConfig, two_tier_topology
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss
from repro.serve import (DecodeEngine, ModelBus, ScheduledModel, replay,
                         synthetic_trace)

from .common import dataset, emit

SEED = 42
MIN_SPEEDUP = 5.0


def _lm_setup(d_model: int = 64, vocab: int = 128):
    """The serving arch: qwen3-14b reduced small enough for CPU CI.

    float32 on purpose: CPU bf16 emulation would slow both paths equally
    and double the bench wall time without changing the comparison.
    """
    cfg = get_config("qwen3-14b").reduced(num_layers=2, d_model=d_model,
                                          vocab_size=vocab, dtype="float32")
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


# ------------------------------------------------------------- throughput

# heavy-tailed serving workload: every LONG_EVERY-th request generates
# LONG_NEW tokens, the rest are one-token lookups.  Under the seed
# lockstep loop each batch of NUM_SLOTS runs LONG_NEW steps to serve one
# long request; the engine retires the shorts immediately and packs all
# the longs into resident slots.
NUM_REQUESTS, LONG_EVERY, LONG_NEW = 64, 8, 160
PROMPT_LEN, MAX_SEQ, NUM_SLOTS, SCAN_CHUNK = 8, 176, 8, 8


def _workload(vocab: int) -> List[tuple]:
    rng = np.random.default_rng(11)
    return [([int(t) for t in rng.integers(0, vocab, PROMPT_LEN)],
             LONG_NEW if i % LONG_EVERY == 0 else 1)
            for i in range(NUM_REQUESTS)]


def _seed_lockstep_tok_per_s(params, reqs, prefill_j, decode_j) -> float:
    """The pre-engine serving path (``launch/serve.py`` before this PR):
    lockstep batches in arrival order, one jit dispatch per token, every
    batch held until its longest request finishes."""
    total = 0
    t0 = time.perf_counter()
    for b in range(0, len(reqs), NUM_SLOTS):
        grp = reqs[b:b + NUM_SLOTS]
        toks = jnp.asarray([r[0] for r in grp], jnp.int32)
        logits, cache = prefill_j(params, {"tokens": toks})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(max(r[1] for r in grp) - 1):
            logits, cache = decode_j(params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        total += sum(r[1] for r in grp)
    return total / max(time.perf_counter() - t0, 1e-9)


def _engine_workload_tok_per_s(eng, reqs) -> float:
    """Continuous batching over the same requests on a warm engine."""
    for prompt, max_new in reqs:
        eng.submit(prompt, max_new)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    assert len(done) == len(reqs)
    return sum(len(c.tokens) for c in done) / max(dt, 1e-9)


def _throughput_record(quick: bool) -> dict:
    cfg, bundle, params = _lm_setup()
    reqs = _workload(cfg.vocab_size)
    reps = 1 if quick else 3
    prefill_j = jax.jit(lambda p, b: bundle.prefill(p, b, MAX_SEQ))
    decode_j = jax.jit(bundle.decode)
    seed_runs = [_seed_lockstep_tok_per_s(params, reqs, prefill_j, decode_j)
                 for _ in range(reps + 1)][1:]         # first run compiles
    bus = ModelBus(params)
    eng = DecodeEngine(cfg, bus, num_slots=NUM_SLOTS, max_seq=MAX_SEQ,
                       scan_chunk=SCAN_CHUNK, prefill_chunk_tokens=PROMPT_LEN,
                       prefill_chunks_per_step=4 * NUM_SLOTS)
    eng_runs = [_engine_workload_tok_per_s(eng, reqs)
                for _ in range(reps + 1)][1:]
    seed_tps, eng_tps = max(seed_runs), max(eng_runs)
    speedup = eng_tps / max(seed_tps, 1e-9)
    batches = (len(reqs) + NUM_SLOTS - 1) // NUM_SLOTS
    return {
        "scenario": "decode_throughput", "arch": cfg.name,
        "num_slots": NUM_SLOTS, "num_requests": len(reqs),
        "long_every": LONG_EVERY, "long_new_tokens": LONG_NEW,
        "scan_chunk": SCAN_CHUNK, "max_seq": MAX_SEQ,
        "seed_decode_calls": batches * (LONG_NEW - 1),
        "engine_decode_steps": eng.stats["decode_steps"] // (reps + 1),
        "seed_tok_per_s": seed_tps, "engine_tok_per_s": eng_tps,
        "speedup_vs_loop": speedup,
        "meets_speedup_5x": bool(speedup >= MIN_SPEEDUP),
    }


# -------------------------------------------------------- publish fidelity

def _run_sim_with_publish(rounds: int):
    """Tiny logreg hier sim; capture every round's published params."""
    ds = dataset("synthetic_1_1")
    lr_params = get_model(ArchConfig(name="lr", family="logreg",
                                     input_dim=ds.x.shape[-1],
                                     num_classes=ds.num_classes)
                          ).init(jax.random.PRNGKey(0))
    fleet = bimodal_fleet(ds.num_devices, slowdown=10.0, dropout_slow=0.05,
                          seed=0)
    published: List[tuple] = []
    from repro.obs import spans

    def publish_fn(t, p):
        published.append((t, p, spans.virtual_now()))

    result = run_hier_simulation(
        "serve_publish", logistic_loss, logistic_apply, lr_params, ds,
        HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                   min_epochs=1, max_epochs=10),
        two_tier_topology(fleet, 4), num_rounds=rounds,
        selection_seed=SEED, eval_every=1, publish_fn=publish_fn)
    return ds, result, published


def _fidelity_record(quick: bool):
    rounds = 4 if quick else 8
    ds, result, published = _run_sim_with_publish(rounds)
    x, y, mask = jnp.asarray(ds.x), jnp.asarray(ds.y), jnp.asarray(ds.mask)
    max_err = 0.0
    for t, p, _ in published:
        loss = global_train_loss(logistic_loss, p, x, y, mask)
        max_err = max(max_err, abs(loss - result.train_loss[t]))
    rec = {
        "scenario": "publish_fidelity", "num_rounds": rounds,
        "num_published": len(published),
        "loss_match_max_abs_err": max_err,
        "meets_loss_match": bool(max_err <= 1e-6),
        "final_loss": result.train_loss[-1],
    }
    return rec, result


# ------------------------------------------------------ hot swap / replay

def _perturb(params, r: float):
    """Deterministic tiny perturbation — distinct versions, same scale."""
    return jax.tree_util.tree_map(lambda a: a * (1.0 + 0.01 * r), params)


def _hot_swap_records(quick: bool, sim_result) -> List[dict]:
    cfg, _, params = _lm_setup()
    bus = ModelBus(params)
    eng = DecodeEngine(cfg, bus, num_slots=4, max_seq=128, scan_chunk=8,
                       prefill_chunk_tokens=16)
    trace = synthetic_trace(num_requests=6 if quick else 12,
                            vocab=cfg.vocab_size, seed=7,
                            mean_interarrival_s=0.3,
                            prompt_len=(4, 16), max_new=(4, 12))
    horizon = trace[-1].arrival_s
    losses = sim_result.train_loss
    schedule = [ScheduledModel(t_publish_s=(r + 1) * horizon / len(losses),
                               params=_perturb(params, r + 1),
                               train_loss=float(losses[r]), round=r)
                for r in range(len(losses))]
    report = replay(eng, trace, schedule, step_cost_s=0.05)
    swap_rec = {
        "scenario": "hot_swap", "arch": cfg.name,
        "num_swaps": report["num_swaps"],
        "num_completed": report["num_completed"],
        "num_versions_published": len(schedule),
        "tokens_generated": report["tokens_generated"],
        "slot_occupancy_mean": report["slot_occupancy_mean"],
        "latency_virtual_mean_s": report["latency_virtual_mean_s"],
        "swap_stall_s_max": eng.stats["swap_stall_s_max"],
        "swap_stall_s_total": eng.stats["swap_stall_s_total"],
    }
    stale_rec = {
        "scenario": "staleness", "arch": cfg.name,
        "staleness_virtual_mean_s": report["staleness_virtual_mean_s"],
        "staleness_virtual_max_s": report["staleness_virtual_max_s"],
        "served_loss_mean": report["served_loss_mean"],
        "tokens_per_virtual_s": report["tokens_per_virtual_s"],
    }
    return [swap_rec, stale_rec]


# ---------------------------------------------------------------- harness

def run(quick: bool = False) -> Dict:
    tp = _throughput_record(quick)
    emit(f"serve/decode/{tp['arch']}/slots{tp['num_slots']}",
         1e6 / max(tp["engine_tok_per_s"], 1e-9),
         f"engine={tp['engine_tok_per_s']:.0f}tok/s;"
         f"loop={tp['seed_tok_per_s']:.0f}tok/s;"
         f"speedup={tp['speedup_vs_loop']:.1f}x")

    fid, sim_result = _fidelity_record(quick)
    emit("serve/publish_fidelity", 0.0,
         f"published={fid['num_published']};"
         f"max_err={fid['loss_match_max_abs_err']:.2e};"
         f"match={fid['meets_loss_match']}")

    swap, stale = _hot_swap_records(quick, sim_result)
    emit("serve/hot_swap", 0.0,
         f"swaps={swap['num_swaps']};completed={swap['num_completed']};"
         f"stall_max={swap['swap_stall_s_max'] * 1e3:.2f}ms")
    emit("serve/staleness", 0.0,
         f"stale_mean={stale['staleness_virtual_mean_s']:.2f}s;"
         f"served_loss={stale['served_loss_mean']:.4f}")

    records = [tp, fid, swap, stale]
    return {
        "benchmark": "serve", "quick": bool(quick),
        "records": records,
        "acceptance": {
            "min_speedup_x": MIN_SPEEDUP,
            "speedup_vs_loop": tp["speedup_vs_loop"],
            "meets_speedup_5x": tp["meets_speedup_5x"],
            "meets_loss_match": fid["meets_loss_match"],
            "num_swaps": swap["num_swaps"],
            "swap_stall_s_max": swap["swap_stall_s_max"],
        },
    }
