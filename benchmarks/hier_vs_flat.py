"""Hierarchical vs flat contextual aggregation: fan-in and depth sweep.

Sweeps the gateway count of a two-tier topology (cloud fan-in) and adds a
three-tier geo-partitioned tree, reporting per configuration: final loss
and accuracy vs the flat (star) baseline, measured cloud-uplink bytes and
the savings ratio, and round-time on the multi-hop critical path.  The
interesting trends: uplink savings grow ~K/(2·P) with fewer gateways, the
loss gap stays small because the mass-conserving γ stage only reallocates
weight, and the extra tier costs latency, not bytes.

The headline perf scenario is the 64-device/4-gateway two-tier fleet of
``examples/edge_hier.py`` (topology "two_tier_64"): its records carry the
fused-round-engine wall-clock stats (``compile_wall_time_s`` /
``steady_wall_time_per_round_s`` — real seconds, ignored by the regression
gate) that the PR-4 ≥3× off-TPU speedup claim is measured on.

Emits ``name,us_per_call,derived`` rows like every other benchmark module;
``collect()`` returns a JSON-ready dict for ``run.py --json``
(→ ``BENCH_hier.json``).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.edge import bimodal_fleet, uniform_fleet
from repro.fl import run_hier_simulation
from repro.hier import (HierConfig, geo_partitioned_topology, star_topology,
                        two_tier_topology)
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss

from .common import dataset, emit

SEED = 42
GATEWAY_COUNTS = (2, 4, 8)


def _params_for(ds):
    return get_model(ArchConfig(name="lr", family="logreg",
                                input_dim=ds.x.shape[-1],
                                num_classes=ds.num_classes)
                     ).init(jax.random.PRNGKey(0))


def _setup():
    ds = dataset("synthetic_1_1")
    return ds, _params_for(ds)


def _setup64():
    """The examples/edge_hier.py fleet: 64 devices, 4 gateways."""
    n_dev = 64
    xs, ys = make_synthetic(1.0, 1.0, num_devices=n_dev,
                            samples_per_device=60, dim=60, seed=0)
    mask = np.ones(ys.shape, np.float32)
    tx = xs.reshape(-1, xs.shape[-1])[:400]
    ty = ys.reshape(-1)[:400]
    ds = FederatedDataset(xs, ys, mask, tx, ty, 10)
    return ds, _params_for(ds)


def _run(name, ds, params, cfg, topo, rounds):
    return run_hier_simulation(name, logistic_loss, logistic_apply, params,
                               ds, cfg, topo, num_rounds=rounds,
                               selection_seed=SEED, eval_every=rounds)


def collect(rounds: int = 20) -> Dict[str, List[dict]]:
    """Run the sweep and return JSON-ready records (also used by --json)."""
    ds, params = _setup()
    n = ds.num_devices
    fleet = bimodal_fleet(n, slowdown=10.0, dropout_slow=0.05, seed=0)
    base = dict(lr=0.2, batch_size=10, min_epochs=1, max_epochs=10)

    flat = _run("flat", ds, params,
                HierConfig(aggregator="hier_contextual", **base),
                star_topology(fleet), rounds)
    records = [{
        "topology": "star", "depth": 1, "gateways": 0, "method": "contextual",
        "final_loss": flat.train_loss[-1], "final_acc": flat.test_acc[-1],
        "cloud_uplink_bytes": flat.cloud_uplink_bytes,
        "uplink_savings": 1.0, "loss_gap_vs_flat": 0.0,
        "round_time_s": flat.times[-1] / rounds,
    }]

    def record(topo, depth, gws, agg, r):
        gap = abs(r.train_loss[-1] - flat.train_loss[-1]) / flat.train_loss[-1]
        records.append({
            "topology": topo, "depth": depth, "gateways": gws, "method": agg,
            "final_loss": r.train_loss[-1], "final_acc": r.test_acc[-1],
            "cloud_uplink_bytes": r.cloud_uplink_bytes,
            "uplink_savings": flat.cloud_uplink_bytes / r.cloud_uplink_bytes,
            "loss_gap_vs_flat": gap,
            "round_time_s": r.times[-1] / rounds,
            # fused-engine real wall-clock (machine-dependent → gate-ignored)
            **r.engine,
        })

    for gws in GATEWAY_COUNTS:              # fan-in sweep, two tiers
        topo = two_tier_topology(fleet, gws)
        for agg in ("hier_contextual", "hier_fedavg"):
            r = _run(f"g{gws}-{agg}", ds, params,
                     HierConfig(aggregator=agg, **base), topo, rounds)
            record("two_tier", 2, gws, agg, r)

    geo = geo_partitioned_topology(uniform_fleet(n), num_regions=2,
                                   gateways_per_region=2)
    r = _run("geo", ds, params,
             HierConfig(aggregator="hier_contextual", **base), geo, rounds)
    record("geo", 3, 4, "hier_contextual", r)

    # headline 64-device/4-gateway scenario (examples/edge_hier.py fleet):
    # wall-clock of the fused round engine rides in the gate-ignored fields
    ds64, params64 = _setup64()
    fleet64 = bimodal_fleet(64, slowdown=10.0, dropout_slow=0.05, seed=0)
    r64 = _run("two_tier_64", ds64, params64,
               HierConfig(aggregator="hier_contextual", **base),
               two_tier_topology(fleet64, 4), rounds)
    records.append({
        "topology": "two_tier_64", "depth": 2, "gateways": 4,
        "method": "hier_contextual", "num_devices_64": 64,
        "final_loss": r64.train_loss[-1], "final_acc": r64.test_acc[-1],
        "cloud_uplink_bytes": r64.cloud_uplink_bytes,
        "round_time_s": r64.times[-1] / rounds,
        **r64.engine,
    })

    return {"benchmark": "hier_vs_flat", "num_devices": n, "rounds": rounds,
            "records": records}


def run(rounds: int = 20) -> Dict[str, List[dict]]:
    results = collect(rounds)
    for rec in results["records"]:
        derived = f"depth={rec['depth']};gw={rec['gateways']};" \
                  f"loss={rec['final_loss']:.4f}"
        if "loss_gap_vs_flat" in rec:
            derived += (f";gap={rec['loss_gap_vs_flat'] * 100:.1f}%;"
                        f"uplink_savings={rec['uplink_savings']:.1f}x")
        if "steady_wall_time_per_round_s" in rec:
            derived += (f";steady_round="
                        f"{rec['steady_wall_time_per_round_s'] * 1e3:.1f}ms")
        emit(f"hier_vs_flat/{rec['topology']}/g{rec['gateways']}/"
             f"{rec['method']}", rec["round_time_s"] * 1e6, derived)
    return results
