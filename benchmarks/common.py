"""Shared benchmark utilities: datasets, runners, CSV emission, and the
tracker hop that makes every bench's JSON a projection of its event trace.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.data import make_femnist_like, make_mnist_like, make_synthetic
from repro.data.federated import FederatedDataset, make_federated
from repro.fl import ServerConfig, SimulationResult, run_simulation
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss
from repro.obs import current_tracker

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def publish_bench(results: Dict) -> None:
    """Stream a bench's JSON-ready results dict into the current tracker as
    marked summary events (``_bench_meta`` / ``_bench_record`` /
    ``_bench_block`` / ``_bench_list``) so ``bench_trace.derive_bench_json``
    can rebuild ``BENCH_<name>.json`` from the trace alone — the jsonl
    stream, not the returned dict, is what ``run.py --json`` commits."""
    tr = current_tracker()
    if not tr.active:
        return
    meta = {k: v for k, v in results.items()
            if not isinstance(v, (list, dict))}
    if meta:
        tr.log_summary({"_bench_meta": meta})
    for rec in results.get("records", []):
        tr.log_summary({"_bench_record": rec})
    for key, val in results.items():
        if key == "records" or not isinstance(val, (list, dict)):
            continue
        if isinstance(val, dict):
            tr.log_summary({"_bench_block": {"key": key, "value": val}})
        else:
            for item in val:
                tr.log_summary({"_bench_list": {"key": key, "value": item}})


def dataset(kind: str, seed: int = 0) -> FederatedDataset:
    """The paper's four datasets (procedural stand-ins, DESIGN.md §3)."""
    if kind == "mnist":
        x, y = make_mnist_like(4000, dim=64, num_classes=10, seed=seed)
        return make_federated(x, y, num_devices=30, num_classes=10,
                              concentration=0.2, seed=seed)
    if kind == "femnist":
        x, y = make_femnist_like(5000, dim=64, num_classes=62, seed=seed)
        return make_federated(x, y, num_devices=30, num_classes=62,
                              concentration=0.2, seed=seed)
    if kind == "synthetic_iid":
        xs, ys = make_synthetic(0.0, 0.0, num_devices=30,
                                samples_per_device=60, dim=60, iid=True,
                                seed=seed)
    elif kind == "synthetic_1_1":
        xs, ys = make_synthetic(1.0, 1.0, num_devices=30,
                                samples_per_device=60, dim=60, seed=seed)
    else:
        raise KeyError(kind)
    mask = np.ones(ys.shape, np.float32)
    tx = xs.reshape(-1, xs.shape[-1])[:400]
    ty = ys.reshape(-1)[:400]
    return FederatedDataset(xs, ys, mask, tx, ty, 10)


def run_fl(name: str, agg: str, ds: FederatedDataset, rounds: int,
           lr: float = 0.2, seed: int = 42, **kw) -> SimulationResult:
    cfg_model = ArchConfig(name="lr", family="logreg",
                           input_dim=ds.x.shape[-1],
                           num_classes=ds.num_classes)
    params = get_model(cfg_model).init(jax.random.PRNGKey(0))
    base = dict(num_devices=ds.num_devices, clients_per_round=10, lr=lr,
                batch_size=10, min_epochs=1, max_epochs=20)
    base.update(kw)
    cfg = ServerConfig(aggregator=agg, **base)
    return run_simulation(name, logistic_loss, logistic_apply, params, ds,
                          cfg, num_rounds=rounds, selection_seed=seed,
                          eval_every=1, collect_alpha=True)


def timeit(fn: Callable, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
