"""Transformer-width rounds: streamed engine vs dense (P, n) round matrices.

Three scenarios ride one JSON (``BENCH_bigmodel.json``):

  * ``accum_oracle``       — streamed (G, C) accumulation vs the dense f32
    oracle: max-|err| is the regression signal (gated; a chunking bug shows
    up here first).
  * ``logreg_64dev_4gw``   — the headline 64-device/4-gateway hier scenario
    run end-to-end on BOTH engines: the streamed loss must match the fused
    loss within the BENCH_hier tolerance band, byte accounting must match
    exactly, and the warm ms/round ratio (gate-ignored, machine-dependent)
    documents the small-model overhead of streaming.
  * ``transformer_stream`` — a P=16 round over transformer-shaped bf16
    update pytrees (quick ≈ 3.7M params for CI; full ≥ 50M — the regime the
    dense engine cannot hold).  Records the deterministic memory model:
    ``peak_round_matrix_bytes`` (streamed, O(P·chunk + P²)) vs
    ``dense_round_matrix_bytes`` (2·P·n·4), the savings factor, and the
    ``meets_mem_target`` ≤ 25% acceptance bit — all compared near-exactly
    by the regression gate.  In quick mode the streamed round delta is also
    diffed against the fused engine on the same data (max-|err| gated).

Emits ``name,us_per_call,derived`` rows; ``collect()`` returns the JSON
records for ``run.py --json``.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solve import SolveConfig
from repro.hier.fused import HierRoundEngine
from repro.hier.streamed import StreamedRoundEngine, dense_round_bytes
from repro.kernels import ops

from .common import emit

SEED = 42
P_ROUND = 16
GATEWAYS = 4
CHUNK = 1 << 18          # 2·16·262144·4 B ≈ 33.5 MB streamed working set


def _transformer_stacked(d_model: int, vocab: int, layers: int, P: int,
                         dtype=jnp.bfloat16, seed: int = 0):
    """Stacked transformer-shaped update/gradient pytrees (leading P axis),
    bf16 like real training deltas; f32 accumulation happens per chunk."""
    shapes = {"embed": (vocab, d_model)}
    for l in range(layers):
        for w in ("wq", "wk", "wv", "wo"):
            shapes[f"layer{l}/{w}"] = (d_model, d_model)
        shapes[f"layer{l}/w_up"] = (d_model, 4 * d_model)
        shapes[f"layer{l}/w_down"] = (4 * d_model, d_model)
        shapes[f"layer{l}/ln"] = (d_model,)
    key = jax.random.PRNGKey(seed)

    def draw(i, shape):
        return (0.01 * jax.random.normal(jax.random.fold_in(key, i),
                                         (P,) + shape)).astype(dtype)

    deltas = {k: draw(i, s) for i, (k, s) in enumerate(shapes.items())}
    grads = {k: draw(i + len(shapes), s)
             for i, (k, s) in enumerate(shapes.items())}
    template = {k: jnp.zeros(s, jnp.float32) for k, s in shapes.items()}
    n = sum(int(np.prod(s)) for s in shapes.values())
    return deltas, grads, template, n


def _cohorts(P: int, gws: int) -> List[List[int]]:
    per = P // gws
    return [list(range(g * per, (g + 1) * per)) for g in range(gws)]


def _round_once(eng, template, deltas, grads, cohorts):
    """One full tier-tree round through the engine-agnostic context API:
    gateway solves → cloud γ stage → combine into the parameters."""
    ctx = eng.begin_round(deltas, grads)
    sums = [ctx.gateway(c) for c in cohorts]
    counts = [float(len(c)) for c in cohorts]
    ghat = ctx.compose_grads([s["ghat"] for s in sums], counts)
    delta, info = ctx.cloud_combo([s["u_bar"] for s in sums], counts, ghat)
    new_params = ctx.apply(template, delta)
    return ctx, delta, new_params, info


def _time_rounds(eng, template, deltas, grads, cohorts, reps: int) -> float:
    _, _, p, _ = _round_once(eng, template, deltas, grads, cohorts)
    jax.block_until_ready(p)                      # warm-up pays the compiles
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _, _, p, _ = _round_once(eng, template, deltas, grads, cohorts)
        jax.block_until_ready(p)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _accum_oracle_record(quick: bool) -> dict:
    P, n = 12, (1 << 16) + 77 if quick else (1 << 20) + 77
    key = jax.random.PRNGKey(3)
    D = jax.random.normal(key, (P, n), jnp.float32)
    GM = jax.random.normal(jax.random.fold_in(key, 1), (P, n), jnp.float32)
    G0, C0 = ops.stream_stats(D, GM, backend="ref")
    G1, C1 = ops.stream_stats(D, GM, backend="xla", block_n=1 << 13)
    return {
        "scenario": "accum_oracle", "num_rows": P, "num_cols": n,
        "accum_max_abs_err_G": float(jnp.max(jnp.abs(G1 - G0))),
        "accum_max_abs_err_C": float(jnp.max(jnp.abs(C1 - C0))),
    }


def _logreg_record(rounds: int) -> dict:
    from repro.data import make_synthetic
    from repro.data.federated import FederatedDataset
    from repro.edge import bimodal_fleet
    from repro.fl import run_hier_simulation
    from repro.hier import HierConfig, two_tier_topology
    from repro.models import get_model
    from repro.models.config import ArchConfig
    from repro.models.logistic import logistic_apply, logistic_loss

    n_dev = 64
    xs, ys = make_synthetic(1.0, 1.0, num_devices=n_dev,
                            samples_per_device=60, dim=60, seed=0)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, xs.shape[-1])[:400],
                          ys.reshape(-1)[:400], 10)
    params = get_model(ArchConfig(name="lr", family="logreg", input_dim=60,
                                  num_classes=10)).init(jax.random.PRNGKey(0))
    fleet = bimodal_fleet(n_dev, slowdown=10.0, dropout_slow=0.05, seed=0)
    cfg = HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                     min_epochs=1, max_epochs=10)
    topo = two_tier_topology(fleet, GATEWAYS)
    runs = {}
    for engine in ("fused", "streamed"):
        runs[engine] = run_hier_simulation(
            engine, logistic_loss, logistic_apply, params, ds, cfg, topo,
            num_rounds=rounds, selection_seed=SEED, eval_every=rounds,
            engine=engine)
    rf, rs = runs["fused"], runs["streamed"]
    warm_f = rf.engine["steady_wall_time_per_round_s"]
    warm_s = rs.engine["steady_wall_time_per_round_s"]
    return {
        "scenario": "logreg_64dev_4gw", "gateways": GATEWAYS,
        "bench_rounds": rounds,
        "final_loss_fused": rf.train_loss[-1],
        "final_loss_streamed": rs.train_loss[-1],
        "loss_gap_streamed_vs_fused": abs(rs.train_loss[-1]
                                          - rf.train_loss[-1]),
        "cloud_uplink_bytes_fused": rf.cloud_uplink_bytes,
        "cloud_uplink_bytes_streamed": rs.cloud_uplink_bytes,
        # machine-dependent (gate-ignored): the ≤1.25× small-model criterion
        "fused_steady_wall_time_per_round_s": warm_f,
        "streamed_steady_wall_time_per_round_s": warm_s,
        "streamed_vs_fused_warm_wall_time_ratio": warm_s / max(warm_f, 1e-9),
    }


def _transformer_record(quick: bool) -> dict:
    if quick:
        d_model, vocab, layers = 256, 2048, 4      # ≈ 3.7M params (CI-sized)
    else:
        d_model, vocab, layers = 1024, 8192, 4     # ≈ 58.7M params
    deltas, grads, template, n = _transformer_stacked(d_model, vocab, layers,
                                                      P_ROUND, seed=1)
    cfg = SolveConfig(beta=5.0, ridge=1e-6)
    cohorts = _cohorts(P_ROUND, GATEWAYS)
    seng = StreamedRoundEngine(template, cfg, "contextual", chunk=CHUNK)
    secs = _time_rounds(seng, template, deltas, grads, cohorts,
                        reps=2 if quick else 3)
    peak = seng.peak_round_bytes(P_ROUND)
    dense = dense_round_bytes(P_ROUND, n)
    rec = {
        "scenario": "transformer_stream", "gateways": GATEWAYS,
        "num_params": n, "num_devices_round": P_ROUND, "chunk_cols": CHUNK,
        "peak_round_matrix_bytes": peak,
        "dense_round_matrix_bytes": dense,
        "peak_savings_vs_dense": dense / peak,
        "meets_mem_target": bool(peak <= 0.25 * dense),
        "streamed_round_time_s": secs,
    }
    if quick:
        # CI-sized: the dense engine still fits — diff the round deltas
        feng = HierRoundEngine(template, cfg, "contextual")
        ctx, sdelta, _, _ = _round_once(seng, template, deltas, grads,
                                        cohorts)
        _, fdelta, _, _ = _round_once(feng, template, deltas, grads,
                                      cohorts)
        rec["delta_max_abs_err"] = float(jnp.max(jnp.abs(
            ctx.materialize(sdelta) - fdelta)))
    return rec


def collect(rounds: int = 16, quick: bool = False) -> Dict[str, List[dict]]:
    records = [_accum_oracle_record(quick), _logreg_record(rounds),
               _transformer_record(quick)]
    return {"benchmark": "bigmodel_round", "quick": quick,
            "rounds": rounds, "records": records}


def run(rounds: int = 16, quick: bool = False) -> Dict[str, List[dict]]:
    results = collect(rounds, quick)
    for rec in results["records"]:
        if rec["scenario"] == "accum_oracle":
            derived = (f"errG={rec['accum_max_abs_err_G']:.2e};"
                       f"errC={rec['accum_max_abs_err_C']:.2e}")
            us = 0.0
        elif rec["scenario"] == "logreg_64dev_4gw":
            derived = (f"gap={rec['loss_gap_streamed_vs_fused']:.4f};"
                       f"warm_ratio="
                       f"{rec['streamed_vs_fused_warm_wall_time_ratio']:.2f}")
            us = rec["streamed_steady_wall_time_per_round_s"] * 1e6
        else:
            derived = (f"n={rec['num_params']};"
                       f"peak={rec['peak_round_matrix_bytes'] / 2 ** 20:.1f}MB;"
                       f"dense={rec['dense_round_matrix_bytes'] / 2 ** 20:.1f}MB;"
                       f"savings={rec['peak_savings_vs_dense']:.1f}x;"
                       f"meets25%={rec['meets_mem_target']}")
            us = rec["streamed_round_time_s"] * 1e6
        emit(f"bigmodel_round/{rec['scenario']}", us, derived)
    return results
