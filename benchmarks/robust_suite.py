"""Adversarial & churn robustness sweep: attack × malicious fraction × churn.

Runs the 64-device acceptance fleet through the flat sync runtime under
each attack model and through the two-tier hierarchical runtime under
attack + churn waves, comparing the robust contextual solve
(``contextual_mom`` — clipping + median-of-means pooling on the (G, c)
cross-term slots) against the plain contextual solve, FedAvg, and the
krum / coordinate-median baselines.

The committed ``BENCH_robust.json`` carries an ``acceptance`` block — loss
inflation (attacked final loss / that aggregator's own clean final loss) at
20% Byzantine on the headline scenario — which the bench-regression CI gate
checks: the robust solve stays within 10% of its clean run while plain
contextual and FedAvg degrade markedly.  Clean-run losses are gated within
the cross-platform band; attacked absolute losses ride along ``*_ungated``
(attack noise is jax-version-sensitive; the inflation ratios and meets_*
booleans are the stable signal).  Scheduler drop counts are deterministic
accounting and gated near-exactly (``num_`` prefix).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

from repro.data import make_synthetic
from repro.data.federated import FederatedDataset
from repro.edge import uniform_fleet
from repro.fl import ServerConfig, run_hier_simulation, run_simulation
from repro.hier import HierConfig, two_tier_topology
from repro.models import get_model
from repro.models.config import ArchConfig
from repro.models.logistic import logistic_apply, logistic_loss
from repro.robust import (ByzantineGauss, LabelFlip, RobustConfig, SignFlip,
                          assign_adversaries, churn_schedule)

from .common import emit

SEED = 42                 # client selection
ADV_SEED = 3              # adversary placement
DIM, N_DEV, N_GW = 20, 64, 4
FRAC = 0.2                # headline malicious fraction
ROBUST = RobustConfig(clip=2.0, pool="mom")
ATTACKS = {               # label → model (param folded into the label so it
    "byzantine_gauss@25": ByzantineGauss(scale=25.0),   # keys identity)
    "sign_flip@2": SignFlip(factor=2.0),
    "label_flip": LabelFlip(),
}
HEADLINE = "byzantine_gauss@25"


def _setup():
    xs, ys = make_synthetic(1.0, 1.0, num_devices=N_DEV,
                            samples_per_device=30, dim=DIM, seed=5)
    ds = FederatedDataset(xs, ys, np.ones(ys.shape, np.float32),
                          xs.reshape(-1, DIM)[:400], ys.reshape(-1)[:400], 10)
    params = get_model(ArchConfig(name="lr", family="logreg", input_dim=DIM,
                                  num_classes=10)).init(jax.random.PRNGKey(0))
    return ds, params


def _flat(name, agg, ds, params, fleet, rounds, attack=None,
          robust: Optional[RobustConfig] = None):
    cfg = ServerConfig(aggregator=agg, num_devices=N_DEV,
                       clients_per_round=16, lr=0.2, batch_size=10,
                       min_epochs=1, max_epochs=4, attack=attack,
                       malicious=fleet.malicious if attack else (),
                       robust=robust)
    return run_simulation(name, logistic_loss, logistic_apply, params, ds,
                          cfg, num_rounds=rounds, selection_seed=SEED,
                          eval_every=rounds)


# (method, aggregator, robust config) — the comparison column
_METHODS = (
    ("contextual", "contextual", None),
    ("contextual_mom", "contextual_mom", ROBUST),
    ("fedavg", "fedavg", None),
    ("krum", "krum", RobustConfig()),
    ("coordinate_median", "coordinate_median", None),
)


def collect(rounds: int = 10) -> Dict:
    ds, params = _setup()
    fleet = assign_adversaries(uniform_fleet(N_DEV), FRAC, seed=ADV_SEED)
    records = []

    def rec(method, attack_label, frac, r, clean_loss=None, churn="none",
            **extra):
        row = {"method": method, "attack": attack_label, "frac": frac,
               "churn": churn, **extra}
        if attack_label == "none" and churn == "none":
            row["final_loss"] = r.train_loss[-1]
            row["final_acc"] = r.test_acc[-1]
        else:           # attacked/churned numbers: volatile across backends
            row["final_loss_ungated"] = r.train_loss[-1]
            row["final_acc_ungated"] = r.test_acc[-1]
            if clean_loss is not None:
                row["inflation_ungated"] = r.train_loss[-1] / clean_loss
        records.append(row)
        return row

    # -- flat: clean anchors, then the headline attack for every method ----
    clean = {}
    for method, agg, rob in _METHODS:
        r = _flat(f"{method}-clean", agg, ds, params, fleet, rounds,
                  robust=rob)
        clean[method] = r.train_loss[-1]
        rec(method, "none", 0.0, r)
    attacked = {}
    for method, agg, rob in _METHODS:
        r = _flat(f"{method}-byz", agg, ds, params, fleet, rounds,
                  attack=ATTACKS[HEADLINE], robust=rob)
        attacked[method] = r.train_loss[-1]
        rec(method, HEADLINE, FRAC, r, clean_loss=clean[method])

    # -- flat: remaining attack types on plain vs robust contextual --------
    for label in ("sign_flip@2", "label_flip"):
        for method, agg, rob in _METHODS[:2]:
            r = _flat(f"{method}-{label}", agg, ds, params, fleet, rounds,
                      attack=ATTACKS[label], robust=rob)
            rec(method, label, FRAC, r, clean_loss=clean[method])

    # -- flat: malicious-fraction sweep on the robust solve ----------------
    for frac in (0.1, 0.3):
        fl_f = assign_adversaries(uniform_fleet(N_DEV), frac, seed=ADV_SEED)
        r = _flat(f"mom-f{frac:g}", "contextual_mom", ds, params, fl_f,
                  rounds, attack=ATTACKS[HEADLINE], robust=ROBUST)
        rec("contextual_mom", HEADLINE, frac, r,
            clean_loss=clean["contextual_mom"])

    # -- hierarchical: robust tier solves under attack + churn waves -------
    hcfg = HierConfig(aggregator="hier_contextual", lr=0.2, batch_size=10,
                      min_epochs=1, max_epochs=4, robust=ROBUST)
    topo = two_tier_topology(fleet, N_GW)

    def hier(name, attack=None, churn=None):
        return run_hier_simulation(name, logistic_loss, logistic_apply,
                                   params, ds, hcfg, topo,
                                   num_rounds=rounds, selection_seed=SEED,
                                   eval_every=rounds, attack=attack,
                                   churn=churn)

    h_clean = hier("hier-mom-clean")
    rec("hier_mom", "none", 0.0, h_clean, topology="two_tier",
        num_dropped=h_clean.dropped, num_arrived=h_clean.arrived)
    t_end = h_clean.times[-1]
    for profile in ("none", "wave", "blackout"):
        churn = None if profile == "none" else churn_schedule(
            profile, N_DEV, t_end, seed=1)
        r = hier(f"hier-mom-byz-{profile}", attack=ATTACKS[HEADLINE],
                 churn=churn)
        rec("hier_mom", HEADLINE, FRAC, r,
            clean_loss=h_clean.train_loss[-1], churn=profile,
            topology="two_tier", num_dropped=r.dropped,
            num_arrived=r.arrived)

    # -- acceptance: loss inflation at 20% Byzantine on the headline run ---
    infl = {m: attacked[m] / clean[m] for m in clean}
    acceptance = {
        "attack": HEADLINE, "frac": FRAC,
        "robust_inflation": infl["contextual_mom"],
        "plain_inflation": infl["contextual"],
        "fedavg_inflation": infl["fedavg"],
        "meets_robust_inflation": bool(infl["contextual_mom"] <= 1.10),
        "meets_plain_degrades": bool(infl["contextual"] >= 1.25),
        "meets_fedavg_degrades": bool(infl["fedavg"] >= 1.5),
    }
    return {"benchmark": "robust_suite", "num_devices": N_DEV,
            "gateways": N_GW, "rounds": rounds, "malicious_seed": ADV_SEED,
            "records": records, "acceptance": acceptance}


def run(rounds: int = 10) -> Dict:
    results = collect(rounds)
    for r in results["records"]:
        loss = r.get("final_loss", r.get("final_loss_ungated"))
        derived = f"loss={loss:.4f}"
        if "inflation_ungated" in r:
            derived += f";inflation={r['inflation_ungated']:.2f}x"
        if "num_dropped" in r:
            derived += f";dropped={r['num_dropped']}"
        emit(f"robust_suite/{r['method']}/{r['attack']}/f{r['frac']:g}"
             f"/{r['churn']}", 0.0, derived)
    acc = results["acceptance"]
    emit("robust_suite/acceptance", 0.0,
         f"mom={acc['robust_inflation']:.2f}x;"
         f"ctx={acc['plain_inflation']:.2f}x;"
         f"fedavg={acc['fedavg_inflation']:.2f}x;"
         f"pass={acc['meets_robust_inflation'] and acc['meets_plain_degrades'] and acc['meets_fedavg_degrades']}")
    return results
