"""Kernel micro-benchmarks: Pallas (interpret) correctness-path timing and
the jnp reference timing at aggregation-realistic sizes.

On this CPU container the interpret-mode numbers measure the Python kernel
body (correctness path), NOT TPU performance — the derived column therefore
reports bytes touched and the arithmetic-intensity analysis that feeds
§Roofline, which is hardware-independent."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.combine import combine_pallas
from repro.kernels.gram import gram_pallas
from repro.kernels.sketch import sketch_apply_pallas
from repro.kernels.topk import topk_select_pallas

from .common import emit, timeit


def run() -> None:
    key = jax.random.PRNGKey(0)
    for K, n in ((10, 1 << 16), (16, 1 << 18), (32, 1 << 18)):
        U = jax.random.normal(key, (K, n), jnp.float32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
        w = jax.random.normal(jax.random.fold_in(key, 2), (n,))
        a = jax.random.normal(jax.random.fold_in(key, 3), (K,))

        bytes_read = (K + 1) * n * 4
        ai = (2 * K * K * n + 2 * K * n) / bytes_read   # FLOPs per byte
        t_ref = timeit(lambda: ref.gram_ref(U, g), iters=10)
        emit(f"kernel/gram_ref/K{K}_n{n}", t_ref,
             f"bytes={bytes_read};flop_per_byte={ai:.2f}")
        t_pal = timeit(lambda: gram_pallas(U, g, interpret=True), iters=3)
        emit(f"kernel/gram_pallas_interp/K{K}_n{n}", t_pal,
             f"single_pass=1;fused_cross_term=1")

        t_ref = timeit(lambda: ref.combine_ref(w, U, a), iters=10)
        emit(f"kernel/combine_ref/K{K}_n{n}", t_ref,
             f"bytes={(K + 2) * n * 4}")
        t_pal = timeit(lambda: combine_pallas(w, U, a, interpret=True), iters=3)
        emit(f"kernel/combine_pallas_interp/K{K}_n{n}", t_pal, "hbm_passes=1")

    # summary-compression paths (repro.compress hot spots): stacked
    # sketch-apply at a gateway-realistic m, and top-k selection
    for K, n, m in ((8, 1 << 16, 1 << 10),):
        U = jax.random.normal(key, (K, n), jnp.float32)
        R = jax.random.normal(jax.random.fold_in(key, 4), (m, n), jnp.float32)
        t_ref = timeit(lambda: ref.sketch_ref(U, R), iters=10)
        emit(f"kernel/sketch_ref/K{K}_n{n}_m{m}", t_ref,
             f"bytes={(K + m) * n * 4};out_floats={K * m}")
        t_pal = timeit(lambda: sketch_apply_pallas(U, R, interpret=True),
                       iters=3)
        emit(f"kernel/sketch_pallas_interp/K{K}_n{n}_m{m}", t_pal,
             "single_pass=1;batched_rows=1")
        v, k = U[0], 512
        t_ref = timeit(lambda: ref.topk_ref(v, k), iters=10)
        emit(f"kernel/topk_ref/n{n}_k{k}", t_ref, f"bytes={n * 4}")
        t_pal = timeit(lambda: topk_select_pallas(v, k, interpret=True),
                       iters=3)
        emit(f"kernel/topk_pallas_interp/n{n}_k{k}", t_pal,
             "chunked_candidates=1")
