"""Kernel micro-benchmarks: per-backend autotune timings at
aggregation-realistic sizes, dumped to ``BENCH_kernels.json``.

For every registry op this clears the autotune cache, dispatches once per
shape (which runs the micro-autotune pass over all eligible backends — off
this container's CPU that is compiled-XLA vs the eager jnp reference;
interpret-mode Pallas is timed separately as the correctness path, never a
candidate), and records:

  * per-backend ``us_per_call_*`` timings and the selected backend — both
    machine-dependent, so the bench-regression gate ignores them;
  * deterministic identity/coverage fields (op, shape, bytes touched,
    backend counts) and the max |err| of the autotuned result vs the
    reference oracle — THE regression signal: a backend that silently
    diverges from the oracle fails the gate.

The derived column keeps the roofline analysis of the seed bench: these are
memory-bound tall-skinny contractions, so bytes-touched and FLOP/byte are
the hardware-independent story.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (autotune_records, clear_autotune_cache, ops, ref)

from .common import emit, timeit


def _err(a, b) -> float:
    return float(np.max(np.abs(np.asarray(a, np.float64)
                               - np.asarray(b, np.float64))))


def _timed(fn):
    """(result, median µs/call of the autotuned dispatch) — the measured
    number the CSV's us_per_call column reports."""
    out = fn()
    return out, timeit(fn, iters=3, warmup=1)


def _pair_err(x, y) -> float:
    return max(_err(x[0], y[0]), _err(x[1], y[1]))


def collect(quick: bool = False) -> Dict[str, List[dict]]:
    clear_autotune_cache()
    key = jax.random.PRNGKey(0)
    records: List[dict] = []

    sizes = ((10, 1 << 14), (16, 1 << 16)) if quick else (
        (10, 1 << 16), (16, 1 << 18), (32, 1 << 18))
    for K, n in sizes:
        U = jax.random.normal(key, (K, n), jnp.float32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
        w = jax.random.normal(jax.random.fold_in(key, 2), (n,))
        a = jax.random.normal(jax.random.fold_in(key, 3), (K,))
        shape = f"K{K}_n{n}"

        out, us = _timed(lambda: ops.gram_and_cross(U, g))
        records.append({
            "op": "gram", "shape": shape, "K": K, "n": n,
            "bytes_touched": (K + 1) * n * 4,
            "flop_per_byte": (2 * K * K * n + 2 * K * n) / ((K + 1) * n * 4),
            "num_backends": len(ops.backends("gram")),
            "us_per_call_dispatch": us,
            "oracle_max_abs_err": _pair_err(out, ref.gram_ref(U, g)),
        })
        out, us = _timed(
            lambda: ops.gram_block_and_cross(U, U[:max(K // 2, 1)], g))
        records.append({
            "op": "gram_block", "shape": shape, "K": K, "n": n,
            "bytes_touched": (K + K // 2 + 1) * n * 4,
            "num_backends": len(ops.backends("gram_block")),
            "us_per_call_dispatch": us,
            "oracle_max_abs_err": _pair_err(
                out, ref.gram_block_ref(U, U[:max(K // 2, 1)], g)),
        })
        out, us = _timed(lambda: ops.weighted_combine(w, U, a))
        records.append({
            "op": "combine", "shape": shape, "K": K, "n": n,
            "bytes_touched": (K + 2) * n * 4,
            "num_backends": len(ops.backends("combine")),
            "us_per_call_dispatch": us,
            "oracle_max_abs_err": _err(out, ref.combine_ref(w, U, a)),
        })

    # summary-compression paths: explicit-matrix sketch, counter-based RNG
    # sign sketch (never materializes R), and top-k selection
    cs = ((8, 1 << 14, 1 << 9),) if quick else ((8, 1 << 16, 1 << 10),)
    for K, n, m in cs:
        U = jax.random.normal(key, (K, n), jnp.float32)
        R = jax.random.normal(jax.random.fold_in(key, 4), (m, n), jnp.float32)
        shape = f"K{K}_n{n}_m{m}"
        out, us = _timed(lambda: ops.sketch_apply(U, R))
        records.append({
            "op": "sketch", "shape": shape, "K": K, "n": n, "m": m,
            "bytes_touched": (K + m) * n * 4,
            "num_backends": len(ops.backends("sketch")),
            "us_per_call_dispatch": us,
            "oracle_max_abs_err": _err(out, ref.sketch_ref(U, R)),
        })
        seed = jnp.uint32(42)
        out, us = _timed(lambda: ops.sign_sketch(U, seed, m))
        records.append({
            "op": "sign_sketch", "shape": shape, "K": K, "n": n, "m": m,
            "bytes_touched": (K * n + K * m) * 4,   # R is never materialized
            "num_backends": len(ops.backends("sign_sketch")),
            "us_per_call_dispatch": us,
            "oracle_max_abs_err": _err(out, ref.rng_sketch_ref(U, seed,
                                                               m=m)),
        })
        v, k = U[0], 512
        out, us = _timed(lambda: ops.topk_select(v, k))
        records.append({
            "op": "topk", "shape": f"n{n}_k{k}", "n": n, "k": k,
            "bytes_touched": n * 4,
            "num_backends": len(ops.backends("topk")),
            "us_per_call_dispatch": us,
            "oracle_max_abs_err": _err(out[0], ref.topk_ref(v, k)[0]),
        })

    # serving decode attention: one token per slot vs a long KV cache with
    # per-slot lengths masking (the DecodeEngine hot path, PR-10)
    ds = ((4, 128),) if quick else ((4, 128), (8, 256))
    for B, S in ds:
        KV, G, hd = 4, 2, 64
        kq = jax.random.fold_in(key, 5)
        q = jax.random.normal(kq, (B, KV, G, hd), jnp.float32)
        kc = jax.random.normal(jax.random.fold_in(kq, 1), (B, S, KV, hd),
                               jnp.float32)
        vc = jax.random.normal(jax.random.fold_in(kq, 2), (B, S, KV, hd),
                               jnp.float32)
        lengths = jnp.arange(1, B + 1, dtype=jnp.int32) * (S // (B + 1))
        out, us = _timed(lambda: ops.flash_decode(q, kc, vc, lengths))
        records.append({
            "op": "flash_decode", "shape": f"B{B}_S{S}_h{KV}x{G}_d{hd}",
            "n": S,
            "bytes_touched": (2 * B * S * KV * hd + B * KV * G * hd) * 4,
            "num_backends": len(ops.backends("flash_decode")),
            "us_per_call_dispatch": us,
            "oracle_max_abs_err": _pair_err(
                out, ref.flash_decode_ref(q, kc, vc, lengths)),
        })

    # the raw autotune cache rides alongside the per-shape records: the
    # per-backend timings + selections per (op, shape-bucket), all
    # machine-dependent and gate-ignored
    autotune = autotune_records()
    return {"benchmark": "kernels", "quick": bool(quick),
            "records": records, "autotune": autotune}


def run(quick: bool = False) -> Dict[str, List[dict]]:
    results = collect(quick)
    for rec in results["records"]:
        emit(f"kernel/{rec['op']}/{rec['shape']}",
             rec["us_per_call_dispatch"],
             f"bytes={rec['bytes_touched']};"
             f"err={rec['oracle_max_abs_err']:.2e};"
             f"backends={rec['num_backends']}")
    for rec in results["autotune"]:
        times = ";".join(f"{k.replace('us_per_call_', '')}="
                         f"{v:.0f}us" for k, v in rec.items()
                         if k.startswith("us_per_call_"))
        emit(f"kernel/autotune/{rec['op']}", 0.0,
             f"selected={rec['backend_selected']};{times}")

    # interpret-mode Pallas timing (correctness path, reported for context —
    # never an autotune candidate off-TPU)
    if not quick:
        from repro.kernels.gram import gram_pallas
        key = jax.random.PRNGKey(0)
        U = jax.random.normal(key, (16, 1 << 16), jnp.float32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (1 << 16,))
        t = timeit(lambda: gram_pallas(U, g, interpret=True), iters=3)
        emit("kernel/gram_pallas_interp/K16_n65536", t, "correctness_path=1")
    return results
