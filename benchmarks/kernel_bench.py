"""Kernel micro-benchmarks: Pallas (interpret) correctness-path timing and
the jnp reference timing at aggregation-realistic sizes.

On this CPU container the interpret-mode numbers measure the Python kernel
body (correctness path), NOT TPU performance — the derived column therefore
reports bytes touched and the arithmetic-intensity analysis that feeds
§Roofline, which is hardware-independent."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.combine import combine_pallas
from repro.kernels.gram import gram_pallas

from .common import emit, timeit


def run() -> None:
    key = jax.random.PRNGKey(0)
    for K, n in ((10, 1 << 16), (16, 1 << 18), (32, 1 << 18)):
        U = jax.random.normal(key, (K, n), jnp.float32)
        g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
        w = jax.random.normal(jax.random.fold_in(key, 2), (n,))
        a = jax.random.normal(jax.random.fold_in(key, 3), (K,))

        bytes_read = (K + 1) * n * 4
        ai = (2 * K * K * n + 2 * K * n) / bytes_read   # FLOPs per byte
        t_ref = timeit(lambda: ref.gram_ref(U, g), iters=10)
        emit(f"kernel/gram_ref/K{K}_n{n}", t_ref,
             f"bytes={bytes_read};flop_per_byte={ai:.2f}")
        t_pal = timeit(lambda: gram_pallas(U, g, interpret=True), iters=3)
        emit(f"kernel/gram_pallas_interp/K{K}_n{n}", t_pal,
             f"single_pass=1;fused_cross_term=1")

        t_ref = timeit(lambda: ref.combine_ref(w, U, a), iters=10)
        emit(f"kernel/combine_ref/K{K}_n{n}", t_ref,
             f"bytes={(K + 2) * n * 4}")
        t_pal = timeit(lambda: combine_pallas(w, U, a, interpret=True), iters=3)
        emit(f"kernel/combine_pallas_interp/K{K}_n{n}", t_pal, "hbm_passes=1")
